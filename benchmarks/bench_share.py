"""Shared-vs-unshared prefix benchmark: does CSE across tenants pay?

For N tenants at overlap fraction f, ``ceil(f*N)`` tenants register ONE
identical chain pattern (maximal prefix overlap — they alias one forest
node chain) and the rest get label-distinct chains (no overlap — each
pays its own nodes, the worst case for sharing overhead).  Each
configuration is served twice through ``ContinuousSearchService`` —
``enable_sharing=True`` vs ``False`` — over the same synthetic stream
with pinned chunk sizes, measuring per-tick cost and the device bytes
held by partial-match tables (slot groups + forest nodes).

Output: ``BENCH_share.json`` at the repo root (schema ``bench_share/
v1``), rows per (sharing, n_tenants, overlap) plus a ``speedup`` block
per (n_tenants, overlap) pair, so per-PR deltas of the dedup win are
machine-trackable.  ``--dry`` emits the same schema at tiny scale (the
CI smoke gate).
"""

from __future__ import annotations

import json
import math
import os
import time

import jax

from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.core.query import QueryGraph
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import StreamConfig, synth_traffic_stream

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_share.json")

CAP = dict(level_capacity=1024, l0_capacity=1024, max_new=256)
WINDOW = 40
N_VLABELS = 8


def tenant_queries(n_tenants: int, overlap: float,
                   n_edge_labels: int = 4) -> list[QueryGraph]:
    """``ceil(overlap * n)`` copies of one 3-chain + label-distinct
    3-chains for the rest (distinct prefix signatures at every depth —
    the non-overlapping tenants must NOT silently alias each other, or
    the unshared baseline rows measure hidden sharing)."""
    n_shared = math.ceil(overlap * n_tenants)
    n_distinct = n_tenants - n_shared
    # distinct = (head-vertex-label offset) x (first-edge label); offsets
    # start at 1 so no distinct tenant collides with the shared pattern
    assert n_distinct <= (N_VLABELS - 1) * n_edge_labels, n_distinct
    out = []
    for i in range(n_tenants):
        wild = QueryGraph.WILDCARD
        if i < n_shared:
            labels, elabels = (0, 1, 2, 0), (wild, wild, wild)
        else:
            d = i - n_shared
            a = 1 + d % (N_VLABELS - 1)
            labels = (a, (a + 1) % N_VLABELS, (a + 2) % N_VLABELS, a)
            elabels = (d // (N_VLABELS - 1), wild, wild)
        out.append(QueryGraph(4, labels, ((0, 1), (1, 2), (2, 3)),
                              edge_labels=elabels,
                              prec=frozenset({(0, 1), (1, 2)})))
    assert len({(q.vertex_labels, q.edge_labels) for q in out}) == \
        (1 if n_shared else 0) + n_distinct
    return out


def table_bytes(svc: ContinuousSearchService) -> int:
    """Device bytes of all partial-match tables: slot groups + forest."""
    total = sum(x.nbytes
                for g in svc._iter_groups()
                for x in jax.tree.leaves(g.sstate))
    if svc.forest is not None:
        total += svc.forest_stats().table_bytes
    return total


def bench_config(sharing: bool, n_tenants: int, overlap: float,
                 n_edges: int, batch: int, tick_cache: SlotTickCache,
                 warmup_ticks: int = 2) -> dict:
    stream = synth_traffic_stream(StreamConfig(
        n_edges=n_edges + warmup_ticks * batch, n_vertices=80,
        n_vertex_labels=N_VLABELS, n_edge_labels=4, seed=23,
        ts_step_max=2))
    svc = ContinuousSearchService(
        slots_per_group=8, backend=JoinBackend.REF,
        enable_sharing=sharing, tick_cache=tick_cache, **CAP)
    for q in tenant_queries(n_tenants, overlap):
        svc.register(q, WINDOW)

    lat, shared_ticks = [], []

    def on_tick(info):
        lat.append(info.latency_ms)
        shared_ticks.append(info.n_shared_prefix_ticks)

    serve = dict(batch_size=batch, min_batch=batch, max_batch=batch,
                 on_tick=on_tick)
    svc.serve_stream(stream[:warmup_ticks * batch], **serve)  # compile+warm
    lat.clear()
    shared_ticks.clear()
    t0 = time.perf_counter()
    svc.serve_stream(stream[warmup_ticks * batch:], **serve)
    wall = time.perf_counter() - t0

    fs = svc.forest_stats()
    lat_sorted = sorted(lat)
    return {
        "bench": "share_tick",
        "sharing": sharing,
        "n_tenants": n_tenants,
        "overlap": overlap,
        "n_groups": len(svc._iter_groups()),
        "n_prefix_nodes": 0 if fs is None else fs.n_nodes,
        "n_shared_prefix_ticks": (shared_ticks[0] if shared_ticks else 0),
        "batch": batch,
        "n_edges": n_edges,
        "n_ticks": len(lat),
        "edges_per_s": round(n_edges / wall, 1),
        "ms_per_tick_mean": round(sum(lat) / max(1, len(lat)), 3),
        "ms_per_tick_p50": round(lat_sorted[len(lat) // 2], 3) if lat else 0.0,
        "table_bytes": table_bytes(svc),
    }


def bench_share_json(reduced: bool = True, dry: bool = False) -> str:
    """Assemble and write ``BENCH_share.json`` at the repo root."""
    if dry:
        n_tenants, overlaps, n_edges, batch = 4, [1.0], 256, 32
    elif reduced:
        n_tenants, overlaps, n_edges, batch = 8, [0.0, 0.5, 1.0], 2048, 64
    else:
        n_tenants, overlaps, n_edges, batch = 16, [0.0, 0.25, 0.5, 0.75,
                                                   1.0], 16384, 128

    tc = SlotTickCache()
    results, speedups = [], []
    for overlap in overlaps:
        pair = {}
        for sharing in (False, True):
            row = bench_config(sharing, n_tenants, overlap, n_edges, batch,
                               tc)
            results.append(row)
            pair[sharing] = row
        speedups.append({
            "n_tenants": n_tenants,
            "overlap": overlap,
            "tick_speedup": round(
                pair[False]["ms_per_tick_mean"]
                / max(pair[True]["ms_per_tick_mean"], 1e-9), 3),
            "bytes_ratio": round(
                pair[True]["table_bytes"]
                / max(pair[False]["table_bytes"], 1), 4),
        })

    doc = {
        "schema": "bench_share/v1",
        "mode": "dry" if dry else ("reduced" if reduced else "full"),
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "note": ("shared vs unshared serve_stream tick cost and device "
                 "table bytes at N tenants x prefix-overlap fraction; "
                 "overlapping tenants alias one SharedPrefixForest node "
                 "chain (repro.core.share), the rest pay their own"),
        "results": results,
        "speedups": speedups,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# BENCH_share.json -> {JSON_PATH} ({len(results)} rows)")
    for s in speedups:
        print(f"#   share_tick overlap={s['overlap']}: "
              f"{s['tick_speedup']}x tick speedup, "
              f"{s['bytes_ratio']}x table bytes "
              f"({n_tenants} tenants)")
    return JSON_PATH


if __name__ == "__main__":
    bench_share_json()
