"""Benchmark harness entry point: one function per paper table/figure.

``python -m benchmarks.run [--full|--dry]`` — reduced scales by default
(CPU CI); CSV per figure goes to stdout and benchmarks/results/, and two
machine-readable trajectories go to the repo root: ``BENCH_join.json``
(kernel-level: backend × shape × slot-count timings plus the fused
compat_join_pairs vs mask+nonzero bytes model — see
``benchmarks.bench_kernels.bench_join_json``), ``BENCH_tick.json``
(engine-level: end-to-end ``serve_stream`` tick cost per backend through
the ``repro.api`` session — see ``benchmarks.bench_service``),
``BENCH_ingest.json`` (ingress-level: ``serve_frontier`` throughput and
tick latency through the fault-tolerant multi-source frontier at 0%/1%/
10% delivery disorder — see ``benchmarks.bench_ingest``),
``BENCH_share.json`` (cross-tenant prefix sharing: shared vs unshared
tick cost and table bytes at N tenants × overlap fraction — see
``benchmarks.bench_share``), ``BENCH_mesh.json`` (replica-sharded
serving: per-replica tick cost vs replica count on an 8-virtual-device
mesh plus full-vs-delta checkpoint manifest bytes — see
``benchmarks.bench_mesh``; self-spawns a subprocess so XLA_FLAGS can
pin the device count before jax initializes), ``BENCH_serve.json``
(full-path load: recorded-traffic replay with planted C2 attack chains
through frontier + coalescer + shared-prefix groups + checkpoints, bare
vs instrumented, proving the obs layer is free when off — see
``benchmarks.bench_serve``) and ``BENCH_analysis.json`` (static-analysis
coverage: files / pallas sites / plans verified and post-baseline
findings per severity — see ``benchmarks.bench_analysis``).

``--dry`` is the CI smoke mode: tiny shapes, only the join + tick +
share + mesh + analysis benches, but the same JSON schemas, so the
emission paths can't rot.

The roofline/dry-run tables (EXPERIMENTS.md §Dry-run/§Roofline) are
produced separately by ``python -m repro.launch.dryrun --all`` and
summarized by ``python -m benchmarks.report_dryrun``.
"""

from __future__ import annotations

import argparse
import time

from benchmarks import (
    bench_analysis,
    bench_engine,
    bench_ingest,
    bench_kernels,
    bench_mesh,
    bench_multiquery,
    bench_serve,
    bench_service,
    bench_share,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger scales (slower)")
    ap.add_argument("--dry", action="store_true",
                    help="smoke mode: tiny shapes, join benches + "
                         "BENCH_join.json only")
    args = ap.parse_args()
    reduced = not args.full

    t0 = time.time()
    if args.dry:
        bench_kernels.bench_join_json(reduced=True, dry=True)
        bench_service.bench_tick_json(reduced=True, dry=True)
        bench_ingest.bench_ingest_json(reduced=True, dry=True)
        bench_share.bench_share_json(reduced=True, dry=True)
        bench_mesh.bench_mesh_json(reduced=True, dry=True)
        bench_serve.bench_serve_json(reduced=True, dry=True)
        bench_analysis.bench_analysis_json(reduced=True, dry=True)
        print(f"# total bench wall time: {time.time() - t0:.1f}s")
        return

    bench_engine.throughput_vs_window(reduced)        # Fig 14
    bench_engine.throughput_vs_query_size(reduced)    # Fig 15
    bench_engine.space_vs_window(reduced)             # Figs 16-17
    bench_engine.concurrency_scaling(reduced)         # Figs 18-19
    bench_engine.optimization_ablations(reduced)      # Fig 20
    bench_engine.selectivity(reduced)                 # Fig 21
    bench_engine.rescan_baseline(reduced)             # Fan-et-al regime
    bench_kernels.compat_join_scaling(reduced)
    bench_kernels.bench_join_json(reduced=reduced)    # BENCH_join.json
    bench_service.bench_tick_json(reduced=reduced)    # BENCH_tick.json
    bench_ingest.bench_ingest_json(reduced=reduced)   # BENCH_ingest.json
    bench_share.bench_share_json(reduced=reduced)     # BENCH_share.json
    bench_mesh.bench_mesh_json(reduced=reduced)       # BENCH_mesh.json
    bench_serve.bench_serve_json(reduced=reduced)     # BENCH_serve.json
    bench_analysis.bench_analysis_json(reduced=reduced)  # BENCH_analysis.json
    bench_multiquery.main(                            # multi-tenant serving
        n_queries=6 if reduced else 12,
        n_edges=3000 if reduced else 20000)
    print(f"# total bench wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
