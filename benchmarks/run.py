"""Benchmark harness entry point: one function per paper table/figure.

``python -m benchmarks.run [--full]`` — reduced scales by default (CPU
CI); CSV per figure goes to stdout and benchmarks/results/.
The roofline/dry-run tables (EXPERIMENTS.md §Dry-run/§Roofline) are
produced separately by ``python -m repro.launch.dryrun --all`` and
summarized by ``python -m benchmarks.report_dryrun``.
"""

from __future__ import annotations

import argparse
import time

from benchmarks import bench_engine, bench_kernels, bench_multiquery


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger scales (slower)")
    args = ap.parse_args()
    reduced = not args.full

    t0 = time.time()
    bench_engine.throughput_vs_window(reduced)        # Fig 14
    bench_engine.throughput_vs_query_size(reduced)    # Fig 15
    bench_engine.space_vs_window(reduced)             # Figs 16-17
    bench_engine.concurrency_scaling(reduced)         # Figs 18-19
    bench_engine.optimization_ablations(reduced)      # Fig 20
    bench_engine.selectivity(reduced)                 # Fig 21
    bench_engine.rescan_baseline(reduced)             # Fan-et-al regime
    bench_kernels.compat_join_scaling(reduced)
    bench_multiquery.main(                            # multi-tenant serving
        n_queries=6 if reduced else 12,
        n_edges=3000 if reduced else 20000)
    print(f"# total bench wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
