"""Analyzer coverage trajectory: ``BENCH_analysis.json``.

Unlike the other BENCH files this does not time a hot path — it records
the *coverage* of the static-analysis gate (``repro.analysis``) so
per-PR deltas are machine-trackable: how many files and pallas_call
sites the passes see, how many plans the corpus sweep verifies, and the
post-baseline findings count per severity.  A PR that adds a kernel
without a contract, or regresses the tree to a non-empty error count,
shows up here even before the CI lint job fails.

Output: ``BENCH_analysis.json`` at the repo root (schema
``bench_analysis/v1``).  ``--dry`` / ``dry=True`` runs the reduced
kernel lattice — same schema, CI smoke gate.
"""

from __future__ import annotations

import json
import os
import time

import jax

from repro.analysis.cli import _default_paths, run_passes
from repro.analysis.findings import load_baseline

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_analysis.json")


def bench_analysis_json(reduced: bool = True, dry: bool = False) -> dict:
    root, baseline_path = _default_paths()
    t0 = time.time()
    report = run_passes(root, fast=dry or reduced)
    report = report.split_by_baseline(load_baseline(baseline_path))
    wall_s = time.time() - t0

    doc = {
        "schema": "bench_analysis/v1",
        "platform": jax.default_backend(),
        "dry": bool(dry),
        "wall_s": round(wall_s, 3),
        "stats": dict(report.stats),
        "findings_by_severity": report.by_severity(),
        "n_suppressed": len(report.suppressed),
    }
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=1)
        fh.write("\n")
    print(f"# BENCH_analysis.json: {report.stats} "
          f"{doc['findings_by_severity']} in {wall_s:.1f}s")
    return doc


if __name__ == "__main__":
    bench_analysis_json(dry=True)
