"""Summarize dry-run JSONs into the §Dry-run / §Roofline tables.

Why an analytic correction exists
---------------------------------
XLA's HloCostAnalysis visits each while-loop body ONCE: with
scan-over-layers (x scan-over-microbatches x scan-over-KV-chunks) the
reported FLOPs undercount by the product of trip counts, while
'bytes accessed' mixes per-iteration and whole-buffer terms.  We
therefore derive the roofline terms from an explicit per-cell analytic
model (formulas below, validated against the raw numbers where loops
don't interfere) and report the raw cost_analysis values alongside.
Collective bytes parsed from HLO get the same trip-count correction
(collectives inside scanned layer bodies fire once per iteration).

    python -m benchmarks.report_dryrun   # writes benchmarks/results/*.md
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.registry import ARCHS
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.launch.cells import lm_param_flops

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _trip_factor(arch, shape) -> float:
    """Static trip-count product of the scans wrapping the hot loop."""
    if arch.family == "lm":
        cfg = arch.config
        if shape.kind == "train":
            return cfg.n_layers * shape.microbatches
        return cfg.n_layers
    return 1.0


def analytic_cell(arch, shape, n_chips: int) -> dict:
    """Per-device FLOPs / HBM bytes / useful-FLOPs model for one cell."""
    tp = 16
    dp = n_chips // tp
    if arch.family == "lm":
        cfg = arch.config
        n_total, n_active = lm_param_flops(cfg)
        b, s = shape.global_batch, shape.seq_len
        h, hd, L = cfg.n_heads, cfg.head_dim, cfg.n_layers
        p2_loc = 2 * n_total / n_chips              # bf16 weights/device
        if shape.kind == "train":
            d_tok = b * s
            mb = shape.microbatches
            flops = (8 * n_active * d_tok + 4 * b * h * hd * s * s * L) / n_chips
            tok_loc = d_tok / mb / dp
            act = tok_loc * cfg.d_model * 2
            bytes_ = (3 * mb * p2_loc               # weights: fwd/replay/bwd
                      + 20 * n_total / n_chips      # fp32 opt state r/w
                      + 14 * L * mb * act)          # layer activations + stacks
            model = 6 * n_active * d_tok
        elif shape.kind == "prefill":
            d_tok = b * s
            flops = (2 * n_active * d_tok + 2 * b * h * hd * s * s * L) / n_chips
            tok_loc = d_tok / dp
            bytes_ = (p2_loc + 8 * L * tok_loc * cfg.d_model * 2
                      + 2 * L * d_tok * cfg.n_kv_heads * hd * 2 / n_chips)
            model = 2 * n_active * d_tok
        else:  # decode
            kv = 2 * L * b * s * cfg.n_kv_heads * hd * 2
            flops = (2 * n_active * b + 4 * b * h * hd * s * L) / n_chips
            bytes_ = p2_loc + kv / n_chips
            model = 2 * n_active * b + 4 * b * cfg.n_kv_heads * hd * s * L
        return dict(flops_dev=flops, bytes_dev=bytes_, model_flops=model)

    if arch.family in ("gnn", "nequip"):
        ex = shape.extra
        if shape.name == "minibatch_lg":
            from repro.models.gnn.sampler import subgraph_shapes
            n, e = subgraph_shapes(ex["batch_nodes"], tuple(ex["fanout"]))
        elif shape.name == "molecule":
            n, e = ex["n_nodes"] * ex["batch"], ex["n_edges"] * ex["batch"]
        else:
            n, e = ex["n_nodes"], ex["n_edges"]
        cfg = arch.config
        dh = getattr(cfg, "d_hidden", getattr(cfg, "channels", 32))
        d_in = ex.get("d_feat", 64)
        L = cfg.n_layers
        # fwd+bwd: per-edge message matmuls + per-node MLPs
        flops = 6 * L * (e * dh * dh + n * dh * max(dh, d_in)) / n_chips
        # node tensors replicated (read in full per device); edge data sharded
        bytes_ = (6 * L * n * max(d_in, dh) * 4) + 10 * L * (e / n_chips) * dh * 4
        model = flops * n_chips
        return dict(flops_dev=flops, bytes_dev=bytes_, model_flops=model)

    # recsys
    cfg = arch.config
    b = shape.global_batch
    if shape.kind == "retrieval":
        nc = shape.extra["n_candidates"]
        fl = 2 * nc * cfg.embed_dim
        return dict(flops_dev=fl / n_chips,
                    bytes_dev=nc * cfg.embed_dim * 4 / n_chips,
                    model_flops=fl)
    d = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    mlp = 0
    for hsz in cfg.mlp:
        mlp += 2 * d * hsz
        d = hsz
    mult = 6 if shape.kind == "train" else 2
    flops = mult / 2 * b * mlp / n_chips
    embed = mult / 2 * b * cfg.n_sparse * cfg.embed_dim * 4 / n_chips
    bytes_ = embed + mult / 2 * b * mlp / 2 * 0  # mlp weights tiny/cached
    bytes_ += mult / 2 * b * (cfg.n_sparse * cfg.embed_dim * 4) / n_chips
    return dict(flops_dev=flops, bytes_dev=bytes_,
                model_flops=mult / 2 * b * mlp)


def load_cells(mesh_name: str) -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(
            RESULTS, "dryrun", mesh_name, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def corrected(rec: dict) -> dict:
    arch = ARCHS[rec["arch"]]
    shape = arch.shape(rec["shape"])
    n = rec["n_chips"]
    a = analytic_cell(arch, shape, n)
    tf = _trip_factor(arch, shape)
    coll_raw = rec.get("collectives", {}).get("total", 0.0)
    coll = coll_raw * tf
    t_c = a["flops_dev"] / PEAK_FLOPS
    t_m = a["bytes_dev"] / HBM_BW
    t_l = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = (a["model_flops"] / n / PEAK_FLOPS) / bound if bound else 0.0
    mem = rec.get("memory", {})
    raw_peak = mem.get("peak_bytes_per_device", 0)
    emu = mem.get("bf16_emulation_f32_bytes", 0)
    # TPU-native floor: args+out plus a third of temp (the emulation twin
    # subtraction is an upper bound on savings — see dryrun.py)
    floor = (mem.get("argument_size_in_bytes", 0)
             + mem.get("output_size_in_bytes", 0)
             - mem.get("alias_size_in_bytes", 0)
             + mem.get("temp_size_in_bytes", 0) / 3)
    tpu_peak = max(raw_peak - emu, floor) if emu else raw_peak
    return {
        "arch": rec["arch"], "shape": rec["shape"], "n_chips": n,
        "ok": rec.get("ok", False), "skip": rec.get("skipped"),
        "tpu_peak_gb": tpu_peak / 1e9,
        "peak_gb": raw_peak / 1e9,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
        "dominant": dom, "roofline_frac": frac,
        "model_flops": a["model_flops"],
        "useful_ratio": a["model_flops"] / (a["flops_dev"] * n),
        "raw_flops_dev": rec.get("cost", {}).get("flops", 0),
        "raw_bytes_dev": rec.get("cost", {}).get("bytes accessed", 0),
        "wire_bytes_dev": coll,
        "trip_factor": tf,
    }


def emit(mesh_name: str = "pod16x16") -> str:
    rows = [corrected(r) for r in load_cells(mesh_name)]
    lines = [
        f"## Roofline table — {mesh_name} "
        f"({rows[0]['n_chips'] if rows else '?'} chips, TPU v5e terms)",
        "",
        "fits = TPU-native peak <= 16 GB (raw CPU-compile peak includes "
        "XLA:CPU's fp32 emulation of bf16 dots; both shown).",
        "",
        "| arch | shape | fits | tpuGB | rawGB | compute s | memory s | "
        "collective s | dominant | roofline | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        fits = "yes" if r["tpu_peak_gb"] <= 16.0 else "NO"
        note = "spec-skip (extra)" if r["skip"] else ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fits} | "
            f"{r['tpu_peak_gb']:.1f} | {r['peak_gb']:.1f} | "
            f"{r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | {r['dominant']} | "
            f"{100 * r['roofline_frac']:.1f}% | {note} |")
    text = "\n".join(lines) + "\n"
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"roofline_{mesh_name}.md"), "w") as f:
        f.write(text)
    print(text)
    return text


if __name__ == "__main__":
    for m in ("pod16x16", "pod2x16x16"):
        if os.path.isdir(os.path.join(RESULTS, "dryrun", m)):
            emit(m)
