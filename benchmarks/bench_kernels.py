"""Kernel-layer microbenchmarks for the compat_join hot path.

Two products:

* ``compat_join_scaling`` — the historical REF-backend CSV
  (benchmarks/results/).
* ``bench_join_json`` — the machine-readable ``BENCH_join.json`` at the
  repo root tracking the perf trajectory across PRs: backend × shape ×
  slot-count timings (REF vs PALLAS_INTERPRET vs PALLAS when a TPU is
  attached) plus the fused ``compat_join_pairs`` vs mask+nonzero
  comparison.  Compiled-PALLAS wall time can only be measured on TPU;
  on CPU the fused path is scored in interpret-comparable terms — the
  bytes-moved model (the fused kernel never materializes the [CA, CB]
  mask in HBM) alongside same-backend interpret timings.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.core.join import compat_mask_ref, extract_pairs
from repro.kernels.compat_join import ops as cj_ops

# repo root = parent of this file's directory
JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_join.json")

NV, NE = 4, 2          # A-side slot widths used throughout
NVB, NEB = 2, 1        # B-side (stream-edge shaped)


def _case(rng, ca, cb, window=200, density_scale=1):
    """Random join inputs shaped like a level join (A table vs batch)."""
    hi = max(int(np.sqrt(ca * cb) * density_scale), 8)
    ba = jnp.asarray(rng.integers(0, hi, (ca, NV)), jnp.int32)
    ea = jnp.asarray(rng.integers(0, 500, (ca, NE)), jnp.int32)
    va = jnp.asarray(rng.random(ca) < 0.7)
    bb = jnp.asarray(rng.integers(0, hi, (cb, NVB)), jnp.int32)
    eb = jnp.asarray(rng.integers(0, 500, (cb, NEB)), jnp.int32)
    vb = jnp.asarray(rng.random(cb) < 0.9)
    rel = rng.random((NV, NVB)) < 0.3
    trel = np.zeros((NE, NEB), np.int8)
    trel[-1, 0] = -1
    return (ba, ea, va, bb, eb, vb), rel, trel, window


def _time_call(f, args, iters):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us/call


def _bytes_model(ca, cb, max_new):
    """HBM bytes moved per join under each pair-extraction path.

    Inputs are int32 tables + validity; the mask path writes the int8
    [CA, CB] mask and immediately re-reads it for ``jnp.nonzero``; the
    fused kernel writes only the compacted pairs + count.
    """
    in_b = 4 * (ca * (NV + NE) + cb * (NVB + NEB) + ca + cb)
    pair_out = 2 * max_new * 4 + 4
    return {
        "input_bytes": in_b,
        "mask_path_bytes": in_b + 2 * ca * cb + pair_out,
        "fused_path_bytes": in_b + pair_out,
    }


def _mask_fn(backend, rel, trel, window):
    if backend == "ref":
        return jax.jit(
            lambda *a: compat_mask_ref(*a, rel, trel, window))
    return jax.jit(lambda *a: cj_ops.compat_mask(
        *a, rel, trel, window, interpret=(backend == "pallas_interpret")))


def _pairs_fused_fn(backend, rel, trel, window, max_new):
    return jax.jit(lambda *a: cj_ops.compat_join_pairs(
        *a, rel, trel, max_new, window,
        interpret=(backend == "pallas_interpret")))


def _pairs_masknz_fn(backend, rel, trel, window, max_new):
    mask = _mask_fn(backend, rel, trel, window)
    return jax.jit(lambda *a: extract_pairs(mask(*a), max_new))


def _backends():
    bs = ["ref", "pallas_interpret"]
    if jax.default_backend() == "tpu":
        bs.append("pallas")
    return bs


def mask_backend_sweep(shapes, iters):
    """compat_mask timings per backend per shape."""
    rng = np.random.default_rng(0)
    rows = []
    for ca, cb in shapes:
        args, rel, trel, window = _case(rng, ca, cb)
        for backend in _backends():
            us = _time_call(_mask_fn(backend, rel, trel, window),
                            args, iters)
            rows.append({
                "bench": "compat_mask", "backend": backend,
                "ca": ca, "cb": cb, "n_slots": 1,
                "us_per_call": round(us, 1),
                "pairs_per_sec": round(ca * cb / (us * 1e-6), 1),
            })
    return rows


def slot_group_sweep(shapes, slot_counts, iters):
    """Vmapped slot-group joins: per-slot traced windows, one stacked
    3-D-grid pallas_call under the PALLAS backends."""
    rng = np.random.default_rng(1)
    rows = []
    for ca, cb in shapes:
        args, rel, trel, _ = _case(rng, ca, cb)
        ba, ea, va, bb, eb, vb = args
        for n_slots in slot_counts:
            bas = jnp.stack([ba] * n_slots)
            ws = jnp.asarray(
                rng.integers(100, 300, (n_slots,)), jnp.int32)
            for backend in _backends():
                if backend == "ref":
                    one = lambda xa, w: compat_mask_ref(
                        xa, ea, va, bb, eb, vb, rel, trel, w)
                else:
                    interp = backend == "pallas_interpret"
                    one = lambda xa, w: cj_ops.compat_mask(
                        xa, ea, va, bb, eb, vb, rel, trel, w,
                        interpret=interp)
                f = jax.jit(jax.vmap(one, in_axes=(0, 0)))
                us = _time_call(f, (bas, ws), iters)
                rows.append({
                    "bench": "slot_group_mask", "backend": backend,
                    "ca": ca, "cb": cb, "n_slots": n_slots,
                    "us_per_call": round(us, 1),
                    "us_per_slot": round(us / n_slots, 1),
                })
    return rows


def pairs_vs_mask(shapes, max_new, iters):
    """Fused compat_join_pairs vs the mask+nonzero two-step, per backend,
    with the bytes-moved model (the interpret-comparable score)."""
    rng = np.random.default_rng(2)
    rows = []
    for ca, cb in shapes:
        args, rel, trel, window = _case(rng, ca, cb)
        model = _bytes_model(ca, cb, max_new)
        for backend in _backends():
            us_mask = _time_call(
                _pairs_masknz_fn(backend, rel, trel, window, max_new),
                args, iters)
            row = {
                "bench": "pairs_vs_mask", "backend": backend,
                "ca": ca, "cb": cb, "max_new": max_new,
                "us_mask_nonzero": round(us_mask, 1),
                **model,
                "fused_bytes_fraction": round(
                    model["fused_path_bytes"] / model["mask_path_bytes"], 4),
                "fused_wins_bytes":
                    model["fused_path_bytes"] < model["mask_path_bytes"],
            }
            if backend != "ref":      # the fused kernel IS the pallas path
                us_fused = _time_call(
                    _pairs_fused_fn(backend, rel, trel, window, max_new),
                    args, iters)
                row["us_fused"] = round(us_fused, 1)
                row["fused_speedup_measured"] = round(us_mask / us_fused, 3)
            rows.append(row)
    return rows


def bench_join_json(reduced: bool = True, dry: bool = False) -> str:
    """Assemble and write ``BENCH_join.json`` at the repo root."""
    if dry:
        mask_shapes = [(128, 64)]
        pair_shapes = [(128, 128), (1024, 1024)]
        slot_counts = [2]
        iters = 2
    elif reduced:
        mask_shapes = [(1024, 64), (1024, 1024), (4096, 64)]
        pair_shapes = [(256, 256), (1024, 1024)]
        slot_counts = [1, 4]
        iters = 5
    else:
        mask_shapes = [(1024, 64), (4096, 256), (4096, 4096)]
        pair_shapes = [(1024, 1024), (4096, 1024)]
        slot_counts = [1, 4, 16]
        iters = 10

    results = []
    results += mask_backend_sweep(mask_shapes, iters)
    results += slot_group_sweep(mask_shapes[:1] if dry else mask_shapes[:2],
                                slot_counts, iters)
    results += pairs_vs_mask(pair_shapes, max_new=256, iters=iters)

    doc = {
        "schema": "bench_join/v1",
        "mode": "dry" if dry else ("reduced" if reduced else "full"),
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "note": ("'pallas' rows appear only when a TPU is attached; on "
                 "CPU the compiled path is scored by the bytes-moved "
                 "model plus PALLAS_INTERPRET timings."),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# BENCH_join.json -> {JSON_PATH} ({len(results)} rows)")
    for r in results:
        if r["bench"] == "pairs_vs_mask":
            print(f"#   pairs_vs_mask {r['backend']} ca={r['ca']} "
                  f"cb={r['cb']}: bytes {r['fused_path_bytes']} vs "
                  f"{r['mask_path_bytes']} "
                  f"(x{r['fused_bytes_fraction']}), "
                  f"us {r.get('us_fused', '-')} vs {r['us_mask_nonzero']}")
    return JSON_PATH


def compat_join_scaling(reduced=True):
    rng = np.random.default_rng(0)
    rows = []
    sizes = [(1024, 64), (4096, 64), (16384, 64), (16384, 256)]
    nv, ne = 4, 2
    rel = rng.random((nv, 2)) < 0.3
    trel = np.zeros((ne, 1), np.int8)
    trel[-1, 0] = -1
    for ca, cb in sizes:
        ba = jnp.asarray(rng.integers(0, 1000, (ca, nv)), jnp.int32)
        ea = jnp.asarray(rng.integers(0, 500, (ca, ne)), jnp.int32)
        va = jnp.asarray(rng.random(ca) < 0.7)
        bb = jnp.asarray(rng.integers(0, 1000, (cb, 2)), jnp.int32)
        eb = jnp.asarray(rng.integers(0, 500, (cb, 1)), jnp.int32)
        vb = jnp.asarray(rng.random(cb) < 0.9)
        f = jax.jit(lambda *a: compat_mask_ref(*a, rel, trel, 200))
        out = f(ba, ea, va, bb, eb, vb)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        iters = 20
        for _ in range(iters):
            out = f(ba, ea, va, bb, eb, vb)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        pairs_per_s = ca * cb * iters / ((time.perf_counter() - t0))
        rows.append([ca, cb, round(us, 1), f"{pairs_per_s:.3e}"])
    return write_csv("kernel_compat_join",
                     ["rows_a", "rows_b", "us_per_call", "pairs_per_sec"],
                     rows)
