"""Kernel-layer microbenchmarks: compat_join reference-backend throughput
across table sizes (the CPU-measurable proxy; the Pallas kernel itself is
exercised via interpret-mode tests and the dry-run cost model)."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.core.join import compat_mask_ref


def compat_join_scaling(reduced=True):
    rng = np.random.default_rng(0)
    rows = []
    sizes = [(1024, 64), (4096, 64), (16384, 64), (16384, 256)]
    nv, ne = 4, 2
    rel = rng.random((nv, 2)) < 0.3
    trel = np.zeros((ne, 1), np.int8)
    trel[-1, 0] = -1
    for ca, cb in sizes:
        ba = jnp.asarray(rng.integers(0, 1000, (ca, nv)), jnp.int32)
        ea = jnp.asarray(rng.integers(0, 500, (ca, ne)), jnp.int32)
        va = jnp.asarray(rng.random(ca) < 0.7)
        bb = jnp.asarray(rng.integers(0, 1000, (cb, 2)), jnp.int32)
        eb = jnp.asarray(rng.integers(0, 500, (cb, 1)), jnp.int32)
        vb = jnp.asarray(rng.random(cb) < 0.9)
        f = jax.jit(lambda *a: compat_mask_ref(*a, rel, trel, 200))
        out = f(ba, ea, va, bb, eb, vb)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        iters = 20
        for _ in range(iters):
            out = f(ba, ea, va, bb, eb, vb)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        pairs_per_s = ca * cb * iters / ((time.perf_counter() - t0))
        rows.append([ca, cb, round(us, 1), f"{pairs_per_s:.3e}"])
    return write_csv("kernel_compat_join",
                     ["rows_a", "rows_b", "us_per_call", "pairs_per_sec"],
                     rows)
