"""Train GAT on a synthetic cora-like citation graph (full-batch) and
verify accuracy beats the majority-class baseline.

    PYTHONPATH=src python examples/gnn_node_classification.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.gat_cora import smoke_config
from repro.data.graphs import synth_cora_like
from repro.launch.cells import make_gnn_train_step
from repro.models.gnn import models as gnn
from repro.optim import AdamWConfig, adamw_init


def main():
    data = synth_cora_like(n_nodes=600, n_edges=3000, d_feat=64,
                           n_classes=5, seed=0)
    cfg = gnn.GNNConfig(arch="gat", n_layers=2, d_in=64, d_hidden=16,
                        n_heads=4, n_classes=5)
    g = {k: jnp.asarray(v) for k, v in data.items()}
    params = gnn.gat_init(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(weight_decay=5e-4)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_gnn_train_step(
        cfg, lambda p, gg, c: gnn.node_classification_loss(p, gg, c),
        ocfg, lr=5e-3))
    for i in range(120):
        params, opt, loss, _ = step(params, opt, g)
        if i % 20 == 0:
            print(f"step {i:3d} loss {float(loss):.4f}")
    logits = gnn.gat_forward(params, g, cfg)
    acc = float((jnp.argmax(logits, -1) == g["labels"]).mean())
    base = float(np.bincount(data["labels"]).max() / len(data["labels"]))
    print(f"train accuracy {acc:.3f} vs majority baseline {base:.3f}")
    assert acc > base + 0.15
    print("OK")


if __name__ == "__main__":
    main()
