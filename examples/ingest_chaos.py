"""Fault-tolerant ingestion through the public API: flaky multi-source
traffic in, exactly-once ordered matches out.

Production streams do not arrive as one tidy pre-ordered list: they come
from several capture points, over transports that disconnect, redeliver,
reorder, and stall.  This example runs the full ingress stack under
deliberately hostile conditions and shows that none of it reaches the
match stream:

  1. one seeded traffic stream is split into three per-source delivery
     scripts, 30% of deliveries displaced late and 10% redelivered
     (``disordered_sources``);
  2. each source is wrapped in ``ChaosSource``, injecting disconnects
     (with cursor rewind on reconnect), duplicate deliveries, extra
     reordering, stalls, and torn batches — all from one seed;
  3. the session's ``IngestFrontier`` reconnects with backoff, dedups by
     sequence cursor, k-way merges by event time (deterministic
     tie-break ladder), and releases events watermark-ordered into the
     engine — every suppressed or dropped delivery counted, never
     silent;
  4. mid-stream the process "crashes"; ``StreamSession.restore`` brings
     the tenants back AND hands over the checkpointed ingest cursors
     (``restored_ingest``), so fresh chaos-wrapped sources resume
     exactly-once — the final match multiset is identical to a run that
     never crashed.

Run:  PYTHONPATH=src python examples/ingest_chaos.py
"""

import tempfile
from collections import Counter

from repro.api import Pattern, StreamSession
from repro.runtime.fault import RetryPolicy, SimulatedFailure
from repro.stream.chaos import ChaosConfig, ChaosSource
from repro.stream.generator import (
    DisorderConfig, StreamConfig, disordered_sources, synth_traffic_stream)
from repro.stream.ingest import ScriptedSource

CAP = dict(level_capacity=2048, l0_capacity=2048, max_new=512)
RETRY = RetryPolicy(max_attempts=8, base_delay_s=0.0, jitter_frac=0.0)
NO_SLEEP = dict(sleep=lambda d: None)   # deterministic, instant backoff


def lateral_pattern():
    return (Pattern("lateral")
            .vertex("entry", label=0).vertex("pivot", label=1)
            .vertex("target", label=2)
            .edge("entry", "pivot").edge("pivot", "target")
            .before(0, 1)
            .window(40))


def chaos_sources(stream, seed):
    """The stream as three disordered delivery scripts, each behind a
    fault-injecting transport (same seed -> same faults, reproducible)."""
    scripts = disordered_sources(stream, DisorderConfig(
        n_sources=3, disorder_frac=0.3, max_delay=6, duplicate_rate=0.1,
        seed=seed))
    return {
        f"tap{i}": ChaosSource(ScriptedSource(f"tap{i}", sc), ChaosConfig(
            seed=seed + i, p_disconnect=0.08, rewind=4, p_duplicate=0.05,
            reorder_span=3, p_reorder=0.2, p_stall=0.05, stall_len=2,
            p_torn=0.05))
        for i, sc in enumerate(scripts)
    }


def main():
    stream = synth_traffic_stream(StreamConfig(
        n_edges=1200, n_vertices=60, n_vertex_labels=3, n_edge_labels=4,
        seed=7, ts_step_max=2))
    ckpt_dir = tempfile.mkdtemp(prefix="tcss_ingest_")

    # ---- reference: the same traffic served pre-ordered, no faults ----
    ref = StreamSession(slots_per_group=4, **CAP)
    ref_matches = []
    ref.register(lateral_pattern(), on_match=ref_matches.append)
    ref.serve(stream, batch_size=64)

    # ---- chaos run, crashing mid-stream ------------------------------
    sess = StreamSession(slots_per_group=4, ckpt_dir=ckpt_dir, **CAP)
    got = []
    sess.register(lateral_pattern(), on_match=got.append)
    frontier = sess.sources(chaos_sources(stream, seed=13),
                            allowed_lateness=80, stall_patience=16,
                            retry=RETRY, **NO_SLEEP)

    def crash_at(info, tick=8):
        if info.tick == tick:
            raise SimulatedFailure(f"injected crash at tick {tick}")

    try:
        sess.serve_frontier(frontier, ckpt_every=3, batch_size=64,
                            on_tick=crash_at)
    except SimulatedFailure as e:
        print(f"crashed: {e}")
    sess.service.ckpt.wait()        # flush in-flight checkpoint writes
    n_before = len(got)

    # ---- restore: tenants + ingest cursors come back ------------------
    sess2 = StreamSession.restore(ckpt_dir)
    (sub,) = sess2.subscriptions()
    sub.on_match = got.append
    # match reports roll back to the durable checkpoint; so do we
    del got[:]
    resumed = sess2.sources(chaos_sources(stream, seed=13),
                            resume=sess2.restored_ingest,
                            allowed_lateness=80, stall_patience=16,
                            retry=RETRY, **NO_SLEEP)
    sess2.serve_frontier(resumed, batch_size=64)

    st = sess2.status()
    ing = resumed.stats()
    print(f"delivered {ing.n_emitted} edges exactly-once "
          f"({n_before} served pre-crash, rest after restore)")
    print(f"suppressed duplicates: {ing.n_duplicates}, "
          f"reconnects survived: {ing.n_reconnects}, "
          f"late drops: {ing.n_late_dropped}")
    print(f"session health: {st.health}")

    # the proof: window contents identical to the never-crashed run
    same = sess2.service.matches(sub.qid) == ref.service.matches(
        ref.subscriptions()[0].qid)
    print(f"window state == fault-free reference: {same}")
    assert same
    assert ing.n_emitted == len(stream) and ing.n_late_dropped == 0
    assert ing.n_duplicates > 0 and ing.n_reconnects > 0

    # every match the restored run reported is a fault-free-run match
    ref_keys = Counter((m.vertices, m.edges) for m in ref_matches)
    got_keys = Counter((m.vertices, m.edges) for m in got)
    assert all(ref_keys[k] >= v for k, v in got_keys.items())
    print(f"post-restore match reports: {len(got)}, all present in the "
          f"reference run")


if __name__ == "__main__":
    main()
