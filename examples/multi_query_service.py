"""Multi-tenant continuous search: many standing queries, one stream,
crash-safe serving.

Demonstrates the unified serving path (ContinuousSearchService):

  1. register several timing-constrained queries (different tenants);
  2. serve a live edge stream with adaptive tick coalescing, collecting
     per-query match deltas as they happen, while the service
     checkpoints itself asynchronously every few ticks;
  3. register a NEW query mid-stream — because it shares a structural
     signature with an existing slot group, no recompilation happens
     (watch ``svc.n_compiles``);
  4. "crash" the server, then ``ContinuousSearchService.restore`` it
     from the newest usable checkpoint: every tenant comes back under
     its original qid, the compiled ticks come from the process-wide
     SlotTickCache (zero recompiles), and replaying the unserved tail
     of the stream misses nothing still inside the window.

Run:  PYTHONPATH=src python examples/multi_query_service.py
"""

import tempfile

from repro.core.query import QueryGraph
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import StreamConfig, synth_traffic_stream

def main():
    # A traffic-like stream: 3 vertex labels (host classes), 4 edge labels
    # (ports).  Think intrusion patterns over flow records.
    stream = synth_traffic_stream(StreamConfig(
        n_edges=2000, n_vertices=60, n_vertex_labels=3, n_edge_labels=4,
        seed=7, ts_step_max=2))
    ckpt_dir = tempfile.mkdtemp(prefix="tcss_ckpt_")

    svc = ContinuousSearchService(
        slots_per_group=4, level_capacity=4096, l0_capacity=4096,
        max_new=1024, ckpt_dir=ckpt_dir)

    # Tenant A: lateral movement — a timing-ordered 2-hop chain 0 -> 1 -> 2.
    chain = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)),
                       prec=frozenset({(0, 1)}))
    # Tenant B: beaconing triangle with a full timing order.
    tri = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2), (2, 0)),
                     prec=frozenset({(0, 1), (1, 2)}))
    qa = svc.register(chain, window=60)
    qb = svc.register(tri, window=80)
    print(f"registered qa={qa} (chain) qb={qb} (triangle); "
          f"compiles so far: {svc.n_compiles}")

    # serve the first half with periodic async checkpoints
    half = len(stream) // 2
    counts = svc.serve_stream(
        stream[:half], ckpt_every=5, batch_size=64)
    print(f"mid-stream: chain={counts.get(qa, 0)} "
          f"triangle={counts.get(qb, 0)} new matches "
          f"(served {svc.n_edges_ingested} edges in {svc.n_ticks} ticks)")

    # Tenant C arrives mid-stream with a *relabeled* chain (hosts of class
    # 2 -> 0 -> 1).  Same structure as tenant A's chain, so registration
    # is a pure slot write: n_compiles must not move.
    before = svc.n_compiles
    chain_c = QueryGraph(3, (2, 0, 1), ((0, 1), (1, 2)),
                         prec=frozenset({(0, 1)}))
    qc = svc.register(chain_c, window=60)
    assert svc.n_compiles == before, "same-structure registration recompiled!"
    print(f"registered qc={qc} mid-stream with NO recompile "
          f"(compiles: {svc.n_compiles})")
    svc.unregister(qb)  # tenant B leaves; its slot is reusable
    svc.checkpoint()    # make the new tenant layout durable
    svc.ckpt.wait()

    # ---- simulated crash: the server object is gone ---------------------
    del svc
    svc = ContinuousSearchService.restore(ckpt_dir)
    print(f"restored from {ckpt_dir}: {svc.n_active} tenants, "
          f"resume offset {svc.n_edges_ingested}, "
          f"recompiles on restore: {svc.n_compiles} (ticks were cached)")

    # replay the unserved tail; a restored server misses nothing in-window
    counts2 = svc.serve_stream(stream[svc.n_edges_ingested:], ckpt_every=5)
    print(f"end of stream: chain={counts.get(qa, 0) + counts2.get(qa, 0)} "
          f"relabeled-chain={counts2.get(qc, 0)} new matches over "
          f"{svc.n_edges_ingested} edges")
    print(f"windowed matches live right now: qa={len(svc.matches(qa))} "
          f"qc={len(svc.matches(qc))}")
    print(f"total slot-group compiles for 3 tenants + churn + crash/"
          f"restore: {svc.n_compiles}")


if __name__ == "__main__":
    main()
