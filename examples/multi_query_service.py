"""Multi-tenant continuous search through the public API: many standing
patterns, one stream, crash-safe serving.

Demonstrates the ``repro.api`` surface end-to-end (the session drives
``ContinuousSearchService`` underneath):

  1. declare timing-constrained patterns with the fluent DSL and
     register them as separate tenants — ``Subscription`` handles give
     typed matches keyed by each pattern's own vertex/edge names;
  2. serve a live edge stream with adaptive tick coalescing while the
     session checkpoints itself asynchronously every few ticks;
  3. register a NEW pattern mid-stream that states the same structure in
     a completely different authoring — the canonicalizing planner maps
     it onto the existing compiled slot tick (watch ``n_compiles``);
  4. "crash" the process, then ``StreamSession.restore``: every tenant
     comes back under its original subscription with the same label
     vocabulary, the compiled ticks come from the process-wide
     SlotTickCache (zero recompiles), and replaying the unserved tail
     of the stream misses nothing still inside the window;
  5. cross-tenant prefix sharing (``share_prefixes=True``): two tenants
     whose patterns share a timing-chain prefix alias ONE set of device
     tables for it (a refcounted SharedPrefixForest node chain advanced
     once per tick) — the forest stats show the dedup.

Run:  PYTHONPATH=src python examples/multi_query_service.py
"""

import tempfile

from repro.api import Pattern, StreamSession
from repro.stream.generator import StreamConfig, synth_traffic_stream


def main():
    # A traffic-like stream: 3 vertex labels (host classes), 4 edge labels
    # (ports).  Think intrusion patterns over flow records.  Raw DataEdges
    # feed straight into the session (they are already in label space).
    stream = synth_traffic_stream(StreamConfig(
        n_edges=2000, n_vertices=60, n_vertex_labels=3, n_edge_labels=4,
        seed=7, ts_step_max=2))
    ckpt_dir = tempfile.mkdtemp(prefix="tcss_ckpt_")

    sess = StreamSession(
        slots_per_group=4, level_capacity=4096, l0_capacity=4096,
        max_new=1024, ckpt_dir=ckpt_dir)

    # Tenant A: lateral movement — a timing-ordered 2-hop chain.
    chain = (Pattern("lateral")
             .vertex("entry", label=0).vertex("pivot", label=1)
             .vertex("target", label=2)
             .edge("entry", "pivot").edge("pivot", "target")
             .before(0, 1)
             .window(60))
    # Tenant B: beaconing triangle with a full timing order.
    tri = (Pattern("beacon")
           .vertex("a", label=0).vertex("b", label=1).vertex("c", label=2)
           .edge("a", "b").edge("b", "c").edge("c", "a")
           .before(0, 1).before(1, 2)
           .window(80))
    sub_a = sess.register(chain)
    sub_b = sess.register(tri)
    print(f"registered {sub_a.name!r} and {sub_b.name!r}; "
          f"compiles so far: {sess.service.n_compiles}")

    # serve the first half with periodic async checkpoints
    half = len(stream) // 2
    counts = sess.serve(stream[:half], ckpt_every=5, batch_size=64)
    st = sess.status()
    print(f"mid-stream: lateral={counts.get(sub_a, 0)} "
          f"beacon={counts.get(sub_b, 0)} new matches "
          f"(served {st.n_edges_ingested} edges in {st.n_ticks} ticks)")

    # Tenant C arrives mid-stream stating the SAME chain structure in a
    # different authoring: reversed edge order, different names, labels
    # permuted onto the hosts.  The planner canonicalizes it onto tenant
    # A's slot group: registration is a pure slot write, no recompile.
    before = sess.service.n_compiles
    chain_c = (Pattern("lateral-reauthored")
               .vertex("x", label=2).vertex("y", label=0)
               .vertex("z", label=1)
               .edge("z", "x", name="hop2")
               .edge("y", "z", name="hop1")
               .before("hop1", "hop2")
               .window(60))
    sub_c = sess.register(chain_c)
    assert sess.service.n_compiles == before, \
        "same-structure registration recompiled!"
    print(f"registered {sub_c.name!r} mid-stream with NO recompile "
          f"(compiles: {sess.service.n_compiles})")
    sub_b.close()       # tenant B leaves; its slot is reusable
    sess.checkpoint()   # make the new tenant layout durable
    sess.close()

    # ---- simulated crash: the session object is gone --------------------
    del sess
    sess = StreamSession.restore(ckpt_dir)
    subs = {s.name: s for s in sess.subscriptions()}
    print(f"restored from {ckpt_dir}: {sorted(subs)} "
          f"at resume offset {sess.resume_offset}, "
          f"recompiles on restore: {sess.service.n_compiles} (ticks cached)")

    # replay the unserved tail; a restored session misses nothing in-window
    counts2 = sess.serve(stream[sess.resume_offset:], ckpt_every=5)
    sub_a2, sub_c2 = subs["lateral"], subs["lateral-reauthored"]
    print(f"end of stream: lateral={counts.get(sub_a, 0) + counts2.get(sub_a2, 0)} "
          f"reauthored-lateral={counts2.get(sub_c2, 0)} new matches over "
          f"{sess.resume_offset} edges")
    for m in sub_a2.matches()[:3]:
        print(f"  live window match: entry={m.bindings['entry']} "
              f"pivot={m.bindings['pivot']} target={m.bindings['target']} "
              f"completed@{m.ts}")
    print(f"windowed matches live right now: "
          f"lateral={len(sub_a2.matches())} "
          f"reauthored={len(sub_c2.matches())}")
    print(f"total slot-group compiles for 3 tenants + churn + crash/"
          f"restore: {sess.service.n_compiles}")

    # ---- cross-tenant prefix sharing ------------------------------------
    # Two intrusion patterns that agree on their first two hops: a full
    # exfil chain (recon -> staging -> exfil) and the shorter staging
    # detector.  With share_prefixes=True the engine CSEs the common
    # 2-edge prefix: ONE shared expansion-list chain serves both tenants,
    # advanced once per tick; the exfil tenant runs only its third hop.
    shared = StreamSession(share_prefixes=True, level_capacity=4096,
                           l0_capacity=4096, max_new=1024)
    exfil = (Pattern("exfil-chain")
             .vertex("recon", label=0).vertex("staging", label=1)
             .vertex("relay", label=2).vertex("drop", label=0)
             .edge("recon", "staging").edge("staging", "relay")
             .edge("relay", "drop")
             .before(0, 1).before(1, 2)
             .window(60))
    staging = (Pattern("staging-only")
               .vertex("a", label=0).vertex("b", label=1)
               .vertex("c", label=2)
               .edge("a", "b").edge("b", "c").before(0, 1)
               .window(60))
    sub_x, sub_s = shared.register(exfil), shared.register(staging)
    fs = shared.service.forest_stats()
    print(f"\nprefix sharing: {fs.n_nodes} shared tables serve "
          f"{fs.n_tenants} tenants ({fs.n_shared_nodes} aliased by both, "
          f"{fs.table_bytes} device bytes)")
    print(f"  {sub_x.name!r}: prefix depth {sub_x.shared_prefix.depth}, "
          f"{sub_x.shared_prefix.n_tenants} tenant(s) on its leaf")
    print(f"  {sub_s.name!r}: prefix depth {sub_s.shared_prefix.depth}, "
          f"{sub_s.shared_prefix.n_tenants} tenants aliasing its chain")
    ticks = []
    counts3 = shared.serve(stream, batch_size=64,
                           on_tick=lambda i: ticks.append(i))
    print(f"  served {len(stream)} edges: "
          f"{counts3.get(sub_x, 0)} exfil + {counts3.get(sub_s, 0)} "
          f"staging matches, {ticks[0].n_shared_prefix_ticks} shared "
          f"prefix ticks per engine tick (vs "
          f"{sub_x.query.n_edges + sub_s.query.n_edges} level advances "
          f"without sharing)")


if __name__ == "__main__":
    main()
