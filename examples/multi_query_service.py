"""Multi-tenant continuous search: many standing queries, one stream.

Demonstrates the service layer built on the multi-query engine:

  1. register several timing-constrained queries (different tenants);
  2. ingest a live edge stream batch-by-batch, collecting per-query
     match deltas as they happen;
  3. register a NEW query mid-stream — because it shares a structural
     signature with an existing slot group, no recompilation happens
     (watch ``svc.n_compiles``);
  4. unregister a tenant and keep serving the rest.

Run:  PYTHONPATH=src python examples/multi_query_service.py
"""

from repro.core.query import QueryGraph
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import StreamConfig, synth_traffic_stream, to_batches


def main():
    # A traffic-like stream: 3 vertex labels (host classes), 4 edge labels
    # (ports).  Think intrusion patterns over flow records.
    stream = synth_traffic_stream(StreamConfig(
        n_edges=2000, n_vertices=60, n_vertex_labels=3, n_edge_labels=4,
        seed=7, ts_step_max=2))
    batches = list(to_batches(stream, 64))

    svc = ContinuousSearchService(
        slots_per_group=4, level_capacity=4096, l0_capacity=4096, max_new=1024)

    # Tenant A: lateral movement — a timing-ordered 2-hop chain 0 -> 1 -> 2.
    chain = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)),
                       prec=frozenset({(0, 1)}))
    # Tenant B: beaconing triangle with a full timing order.
    tri = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2), (2, 0)),
                     prec=frozenset({(0, 1), (1, 2)}))
    qa = svc.register(chain, window=60)
    qb = svc.register(tri, window=80)
    print(f"registered qa={qa} (chain) qb={qb} (triangle); "
          f"compiles so far: {svc.n_compiles}")

    counts = {qa: 0, qb: 0}
    half = len(batches) // 2
    for b in batches[:half]:
        for qid, res in svc.ingest(b).items():
            counts[qid] += int(res.n_new_matches)
    print(f"mid-stream: chain={counts[qa]} triangle={counts[qb]} new matches")

    # Tenant C arrives mid-stream with a *relabeled* chain (hosts of class
    # 2 -> 0 -> 1).  Same structure as tenant A's chain, so registration
    # is a pure slot write: n_compiles must not move.
    before = svc.n_compiles
    chain_c = QueryGraph(3, (2, 0, 1), ((0, 1), (1, 2)),
                         prec=frozenset({(0, 1)}))
    qc = svc.register(chain_c, window=60)
    assert svc.n_compiles == before, "same-structure registration recompiled!"
    print(f"registered qc={qc} mid-stream with NO recompile "
          f"(compiles: {svc.n_compiles})")

    svc.unregister(qb)  # tenant B leaves; its slot is reusable
    counts[qc] = 0
    for b in batches[half:]:
        for qid, res in svc.ingest(b).items():
            counts[qid] += int(res.n_new_matches)

    print(f"end of stream: chain={counts[qa]} relabeled-chain={counts[qc]} "
          f"new matches over {svc.n_edges_ingested} edges")
    print(f"windowed matches live right now: qa={len(svc.matches(qa))} "
          f"qc={len(svc.matches(qc))}")
    print(f"total slot-group compiles for 3 tenants + churn: {svc.n_compiles}")


if __name__ == "__main__":
    main()
