"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
on the synthetic planted-bigram corpus and verify the loss drops well
below the unigram entropy floor (the model must learn the planted
structure, not just frequencies).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

~100M params: 12L x d=768 x ffn 2048 x vocab 8192. On the 1-core CPU CI
box we default to the 'small' profile; pass --profile 100m on real
hardware. Checkpoints + restart recovery come from the same
FaultTolerantLoop used at pod scale.
"""

import argparse
import tempfile

import jax.numpy as jnp

from repro.launch.train import train_lm
from repro.models.transformer import LMConfig

PROFILES = {
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab=8192, batch=32, seq=256),
    "10m": dict(n_layers=6, d_model=320, n_heads=8, n_kv_heads=4,
                head_dim=40, d_ff=1024, vocab=2048, batch=16, seq=128),
    "small": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                  head_dim=32, d_ff=384, vocab=512, batch=16, seq=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--profile", default="small", choices=PROFILES)
    args = ap.parse_args()
    p = dict(PROFILES[args.profile])
    batch, seq = p.pop("batch"), p.pop("seq")
    cfg = LMConfig(name=f"lm-{args.profile}", dtype=jnp.float32,
                   attn_chunk=seq, remat="none", **p)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        params, losses = train_lm(
            cfg, n_steps=args.steps, batch=batch, seq=seq,
            ckpt_dir=ckpt_dir, ckpt_every=100, log_every=20)
    first, last = losses[0][1], losses[-1][1]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first * 0.8, "model failed to learn planted structure"
    print("OK: loss dropped; planted bigram structure learned")


if __name__ == "__main__":
    main()
