"""Wide&Deep CTR serving example: train briefly on the planted-signal
synthetic CTR stream, then run batched online inference + retrieval.

    PYTHONPATH=src python examples/serve_recsys.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.wide_deep import smoke_config
from repro.data.recsys import recsys_batch
from repro.launch.cells import make_recsys_train_step
from repro.models.recsys import wide_deep as wd
from repro.optim import AdamWConfig, adamw_init


def main():
    cfg = smoke_config()
    params = wd.init(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(state_mode="factored")
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_recsys_train_step(cfg, ocfg, lr=3e-3))
    for i in range(150):
        b = {k: jnp.asarray(v) for k, v in recsys_batch(
            i, 256, cfg.n_sparse, cfg.vocab_per_field, cfg.n_dense,
            cfg.n_wide_crosses).items()}
        params, opt, loss, _ = step(params, opt, b)
        if i % 30 == 0:
            print(f"step {i:3d} bce {float(loss):.4f}")

    # online inference: AUC-ish sanity on held-out batch
    b = {k: jnp.asarray(v) for k, v in recsys_batch(
        10_000, 2048, cfg.n_sparse, cfg.vocab_per_field, cfg.n_dense,
        cfg.n_wide_crosses).items()}
    scores = np.asarray(wd.forward(params, b, cfg))
    y = np.asarray(b["labels"])
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(len(scores))
    n1, n0 = y.sum(), (1 - y).sum()
    auc = (ranks[y == 1].sum() - n1 * (n1 - 1) / 2) / (n1 * n0)
    print(f"held-out AUC {auc:.3f}")
    assert auc > 0.6, "planted CTR signal not learned"

    # retrieval: top-k against a candidate table
    cands = jax.random.normal(jax.random.PRNGKey(2), (5000, cfg.embed_dim))
    user = jax.random.normal(jax.random.PRNGKey(3), (cfg.embed_dim,))
    vals, idx = wd.retrieval_score(user, cands, top_k=10)
    print(f"retrieval top-1 score {float(vals[0]):.3f} @ cand {int(idx[0])}")
    print("OK")


if __name__ == "__main__":
    main()
