"""The paper's Figure-1 scenario: detect an information-exfiltration
attack pattern (victim -> compromised site -> malware download -> C&C
registration -> command -> exfiltration) in network traffic, where the
five steps must occur in strict timing order t1 < ... < t5.

We synthesize background traffic, plant attack instances, and serve the
pattern as a continuous query through the StreamServer (with adaptive
tick coalescing + checkpointing). Every planted attack must be found.

    PYTHONPATH=src python examples/cybersec_c2_detection.py
"""

import numpy as np

from repro.core.oracle import DataEdge
from repro.core.plan import compile_plan
from repro.core.query import QueryGraph
from repro.launch.stream_serve import StreamServer
from repro.stream.generator import StreamConfig, synth_traffic_stream

# vertex labels: 0=victim IP, 1=web server, 2=malware host, 3=C&C server
VICTIM, WEB, MAL, CC = 0, 1, 2, 3
# edge labels (ports/protocols): 0=http, 1=download, 2=register, 3=cmd, 4=exfil
HTTP, DL, REG, CMD, EXFIL = 0, 1, 2, 3, 4


def attack_query() -> QueryGraph:
    """v -(http)-> w; m -(dl)-> v; v -(reg)-> c; c -(cmd)-> v;
    v -(exfil)-> c2, with timing chain e0 ≺ e1 ≺ e2 ≺ e3 ≺ e4 (Figure 1).

    Exfiltration targets a separate collector vertex carrying the C&C
    label (C&C infra uses distinct ingest hosts; also keeps the query a
    simple graph — no duplicate (v, c) edge)."""
    return QueryGraph(
        n_vertices=5,
        vertex_labels=(VICTIM, WEB, MAL, CC, CC),
        edges=((0, 1), (2, 0), (0, 3), (3, 0), (0, 4)),
        edge_labels=(HTTP, DL, REG, CMD, EXFIL),
        prec=frozenset({(0, 1), (1, 2), (2, 3), (3, 4)}),
    )


def plant_attacks(stream, n_attacks, n_vertices, rng):
    """Insert attack chains with correct timing into background traffic."""
    out = list(stream)
    span = out[-1].ts
    planted = []
    for a in range(n_attacks):
        v, w, m, c, c2 = rng.choice(n_vertices, 5, replace=False) + n_vertices
        t0 = int(rng.integers(10, span - 40))
        steps = [
            DataEdge(int(v), int(w), t0, VICTIM, WEB, HTTP),
            DataEdge(int(m), int(v), t0 + 3, MAL, VICTIM, DL),
            DataEdge(int(v), int(c), t0 + 7, VICTIM, CC, REG),
            DataEdge(int(c), int(v), t0 + 11, CC, VICTIM, CMD),
            DataEdge(int(v), int(c2), t0 + 15, VICTIM, CC, EXFIL),
        ]
        out.extend(steps)
        planted.append(steps)
    out.sort(key=lambda e: e.ts)
    return out, planted


def main():
    rng = np.random.default_rng(7)
    background = synth_traffic_stream(StreamConfig(
        n_edges=8000, n_vertices=200, n_vertex_labels=4, n_edge_labels=5,
        seed=3, ts_step_max=1))
    stream, planted = plant_attacks(background, n_attacks=12,
                                    n_vertices=200, rng=rng)

    q = attack_query()
    plan = compile_plan(q, window=60, level_capacity=16384,
                        l0_capacity=16384, max_new=4096)
    print(f"attack pattern: {q.n_edges} edges, "
          f"{len(plan.subqueries)} TC-subquery(ies) "
          f"(a pure ≺-chain compiles to a single expansion list)")

    hits = []
    server = StreamServer(plan)
    total = server.ingest(
        stream, on_match=lambda b, t: hits.append((b.copy(), t.copy())))
    # StreamServer routes through repro.api: the typed handle is one
    # property away (overflow status, named bindings via .matches())
    sub = server.subscription
    print(f"{len(stream)} packets scanned, {total} attack instances found "
          f"(subscription {sub.status}, overflow={sub.n_overflow})")
    assert total >= 12, "planted attacks missed!"
    # verify a reported match is a real planted chain
    found_ts = {tuple(int(x) for x in t) for _, ts in hits for t in ts}
    planted_ts = {tuple(e.ts for e in steps) for steps in planted}
    assert planted_ts <= found_ts, "planted timing chains not all reported"
    print("all planted C&C chains detected, timing order verified")


if __name__ == "__main__":
    main()
