"""Public-API quickstart: the full lifecycle in one file.

This is the exact code shown in the repo-root README and the smoke step
CI runs on every push (JAX_PLATFORMS=cpu): declare a timing-constrained
pattern with the DSL, register it in a StreamSession, ingest typed
events, read typed matches, crash, restore, and keep serving without
missing anything still inside the window.

Run:  PYTHONPATH=src python examples/api_quickstart.py
"""

import tempfile

from repro.api import Event, Pattern, StreamSession


def main():
    # Lateral movement: a login that is strictly followed by a transfer
    # through the compromised host, both within a 300-tick window.
    pattern = (Pattern("lateral-movement")
               .edge("attacker", "host", label="login")
               .edge("host", "server", label="xfer")
               .before(0, 1)            # login strictly precedes xfer
               .window(300))

    ckpt_dir = tempfile.mkdtemp(prefix="tcss_quickstart_")
    sess = StreamSession(ckpt_dir=ckpt_dir)
    sub = sess.register(pattern)

    # Another tenant authors the SAME structure differently — reversed
    # edge order, different names.  The canonicalizing planner maps both
    # onto one compiled slot tick: registration is a pure data write.
    other = (Pattern("exfil")
             .edge("pivot", "target", label="xfer", name="out")
             .edge("entry", "pivot", label="login", name="in")
             .before("in", "out")
             .window(300))
    sub2 = sess.register(other)
    assert sess.service.n_compiles == 1, "isomorphic patterns share a tick"

    events = [
        Event(src=1, dst=2, ts=10, label="login"),
        Event(src=7, dst=8, ts=15, label="probe"),
        Event(src=2, dst=9, ts=40, label="xfer"),     # completes the chain
        Event(src=3, dst=4, ts=60, label="login"),
    ]
    sess.ingest(events)
    for m in sub.drain():
        print(f"match: attacker={m.bindings['attacker']} "
              f"host={m.bindings['host']} server={m.bindings['server']} "
              f"login@{m.times['e0']} xfer@{m.times['e1']}")
    assert len(sub2.drain()) == 1        # same structure, same match

    # make the session durable, then "crash"
    sess.checkpoint()
    sess.close()
    del sess, sub, sub2

    # restore: same qids, same vocab, same pattern plans — and nothing
    # still inside the window is missed on replay
    sess = StreamSession.restore(ckpt_dir)
    sub, sub2 = sess.subscriptions()
    print(f"restored {len(sess.subscriptions())} subscriptions at "
          f"offset {sess.resume_offset}")
    sess.ingest([Event(src=4, dst=5, ts=70, label="xfer")])  # 3->4->5 chain
    (m,) = sub.drain()
    print(f"post-restore match: {m.bindings} at ts={m.ts}")
    assert m.bindings == {"attacker": 3, "host": 4, "server": 5}
    assert len(sub.matches()) == 2       # both chains live in the window
    print("quickstart OK")


if __name__ == "__main__":
    main()
