"""Quickstart: register a timing-constrained continuous query and stream
edges through the engine.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import compile_plan
from repro.core.engine import build_tick, current_matches
from repro.core.query import QueryGraph
from repro.core.state import init_state, make_batch
from repro.stream.generator import StreamConfig, synth_traffic_stream, to_batches


def main():
    # Query: a -> b -> c where the first hop must precede the second
    # (vertex labels 0, 1, 2; timing order e0 ≺ e1).
    q = QueryGraph(
        n_vertices=3,
        vertex_labels=(0, 1, 2),
        edges=((0, 1), (1, 2)),
        prec=frozenset({(0, 1)}),
    )
    window = 30
    plan = compile_plan(q, window)
    print(f"query compiled: {len(plan.subqueries)} TC-subquery(ies), "
          f"decomposition sizes {plan.decomposition_sizes}")

    tick = jax.jit(build_tick(plan))
    state = init_state(plan)

    stream = synth_traffic_stream(StreamConfig(
        n_edges=2000, n_vertices=30, n_vertex_labels=3, n_edge_labels=2,
        seed=1))
    total = 0
    for b in to_batches(stream, 64):
        state, res = tick(state, make_batch(**b))
        total += int(res.n_new_matches)
    print(f"processed {len(stream)} edges, "
          f"reported {total} timing-constrained matches")
    print(f"matches live in the current window: "
          f"{len(current_matches(plan, state))}")
    assert total > 0


if __name__ == "__main__":
    main()
